"""Static vs adaptive scheduling on a synthetic skewed workload (§III-C/G).

The workload is the paper's pathological case: bright blended galaxies
clustered in one corner of the field, so true per-source cost is heavily
skewed in a way the *default* cost model mispredicts.  Optionally one
shard is a straggler (relative speed < 1).

Both schedulers see identical information at the start — catalog features
and the default cost model, exactly what ``run_inference`` has:

  * **static**: one ``decompose.make_plan`` up front, executed to the end
    (the pre-adaptive ``run_inference`` behavior);
  * **adaptive**: the ``DynamicScheduler`` loop — plan the next round,
    measure true per-task cost, ``record`` it (cost-model refit +
    straggler discounting), re-pack the remainder.

Shard wall time per round is Σ task cost ÷ shard speed (the same sum
semantics ``DynamicScheduler.record`` uses for measured shard times).
Emits a JSON comparison: per-round measured/predicted imbalance history,
total time, and sources/sec for both paths.

    PYTHONPATH=src python benchmarks/scheduler_adaptive.py [--smoke]
"""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import argparse
import json

import numpy as np

from repro.core import decompose
from repro.runtime.scheduler import DynamicScheduler


def make_skewed_workload(seed=0, n=2048, extent=4096.0, corner_frac=0.3,
                         corner_area=0.15):
    """Positions + features + true costs with a bright blended corner.

    ``corner_frac`` of the sources sit in the ``corner_area``-sided corner
    square, are ~e²× brighter and heavily blended; true cost is linear in
    the features (so it is *learnable*) with a multiplicative noise tail.
    Returns (positions [n,2], feats [n,4], true_costs [n]).
    """
    rng = np.random.default_rng(seed)
    n_corner = int(n * corner_frac)
    corner = rng.uniform(0, extent * corner_area, (n_corner, 2))
    rest = rng.uniform(0, extent, (n - n_corner, 2))
    pos = np.concatenate([corner, rest])
    in_corner = np.arange(n) < n_corner

    log_flux = rng.normal(3.0, 0.8, n) + np.where(in_corner, 2.0, 0.0)
    prob_gal = np.where(in_corner, rng.uniform(0.6, 1.0, n),
                        rng.uniform(0.0, 1.0, n))
    n_neighbors = (rng.poisson(0.4, n)
                   + np.where(in_corner, rng.poisson(4.0, n), 0)).astype(
                       float)
    feats = decompose.CostModel.features(log_flux, prob_gal, n_neighbors)
    true_coef = np.array([2.0, 3.0, 5.0, 7.0])
    costs = (feats @ true_coef) * rng.lognormal(0.0, 0.1, n)
    return pos, feats, np.maximum(costs, 1.0)


def _measure_round(b, true_costs, node_speed):
    """(shard_times [shards], scheduled idx, per-task measured, shard_of)."""
    tgt, shard_of, _ = decompose.round_tasks(b)
    measured = true_costs[tgt] / node_speed[shard_of]
    shard_times = np.bincount(shard_of, weights=measured,
                              minlength=b.shape[0])
    return shard_times, tgt, measured, shard_of


def _imb(t):
    mean = max(t.mean(), 1e-12)
    return float((t.max() - mean) / mean)


def _summarize(name, imb_hist, pred_hist, round_max, n):
    total = float(sum(round_max))
    return {
        "strategy": name,
        "rounds": len(imb_hist),
        "imbalance_history": [round(v, 4) for v in imb_hist],
        "predicted_imbalance_history": [round(v, 4) for v in pred_hist],
        "final_round_imbalance": imb_hist[-1] if imb_hist else 0.0,
        "mean_imbalance": float(np.mean(imb_hist)) if imb_hist else 0.0,
        "total_time": total,
        "sources_per_sec": n / total if total else 0.0,
    }


def run_static(pos, feats, true_costs, shards, batch, node_speed,
               extent):
    """One up-front plan from the default cost model, speed-unaware."""
    cm = decompose.CostModel()
    plan = decompose.make_plan(pos, cm.predict(feats), shards, batch,
                               extent=extent)
    imb_hist, pred_hist, round_max = [], [], []
    for r, b in enumerate(plan.batches):
        shard_times, *_ = _measure_round(b, true_costs, node_speed)
        imb_hist.append(_imb(shard_times))
        pred_hist.append(plan.round_imbalance(r))
        round_max.append(shard_times.max())
    return _summarize("static", imb_hist, pred_hist, round_max,
                      pos.shape[0])


def run_adaptive(pos, feats, true_costs, shards, batch, node_speed,
                 extent):
    """The closed loop: plan next round → measure → record → re-pack."""
    sched = DynamicScheduler(num_shards=shards, batch=batch)
    imb_hist, pred_hist, round_max = [], [], []
    remaining = np.arange(pos.shape[0])
    r = 0
    while remaining.size:
        plan = sched.plan_round(pos[remaining], feats[remaining],
                                extent=extent)
        b = decompose.globalize(plan.batches[0], remaining)
        shard_times, tgt, measured, shard_of = _measure_round(
            b, true_costs, node_speed)
        sched.record(r, feats[tgt], measured, shard_of, plan=plan)
        imb_hist.append(_imb(shard_times))
        pred_hist.append(plan.round_imbalance(0))
        round_max.append(shard_times.max())
        remaining = np.setdiff1d(remaining, tgt, assume_unique=True)
        r += 1
    out = _summarize("adaptive", imb_hist, pred_hist, round_max,
                     pos.shape[0])
    out["final_shard_speed"] = [round(v, 3) for v in sched.shard_speed]
    out["cost_model_coef"] = [round(v, 3)
                              for v in sched.cost_model.coef]
    return out


def compare(seed=0, n=2048, shards=8, batch=16, extent=4096.0,
            straggler_speed=0.6):
    pos, feats, true_costs = make_skewed_workload(seed=seed, n=n,
                                                  extent=extent)
    node_speed = np.ones(shards)
    if straggler_speed is not None:
        node_speed[-1] = straggler_speed
    args = (pos, feats, true_costs, shards, batch, node_speed, extent)
    st, ad = run_static(*args), run_adaptive(*args)
    return {
        "config": {"seed": seed, "sources": n, "shards": shards,
                   "batch": batch, "straggler_speed": straggler_speed},
        "static": st,
        "adaptive": ad,
        "improvement": {
            "final_round_imbalance": (st["final_round_imbalance"]
                                      - ad["final_round_imbalance"]),
            "mean_imbalance": st["mean_imbalance"] - ad["mean_imbalance"],
            "speedup": st["total_time"] / max(ad["total_time"], 1e-12),
        },
    }


def main_csv():
    """Suite-runner entry (benchmarks/run.py): one CSV row, no argparse
    (run.py's argv must not leak into this benchmark's parser)."""
    out = compare()
    st, ad = out["static"], out["adaptive"]
    print(f"scheduler.adaptive,{ad['total_time'] * 1e6:.1f},"
          f"static_imb={st['mean_imbalance']:.3f};"
          f"adaptive_imb={ad['mean_imbalance']:.3f};"
          f"static_sps={st['sources_per_sec']:.2f};"
          f"adaptive_sps={ad['sources_per_sec']:.2f};"
          f"speedup={out['improvement']['speedup']:.2f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sources", type=int, default=2048)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-speed", type=float, default=0.6)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + assert the adaptive loop wins "
                         "(CI guard that the scheduler path stays live)")
    args = ap.parse_args()

    if args.smoke:
        out = compare(seed=args.seed, n=512, shards=4, batch=16)
    else:
        out = compare(seed=args.seed, n=args.sources, shards=args.shards,
                      batch=args.batch,
                      straggler_speed=args.straggler_speed)
    print(json.dumps(out, indent=1))

    if args.smoke:
        imp = out["improvement"]
        assert imp["final_round_imbalance"] > 0.0, \
            "adaptive final-round imbalance should beat static"
        assert imp["mean_imbalance"] > 0.0, \
            "adaptive mean imbalance should beat static"
        assert imp["speedup"] > 1.0, \
            "adaptive total time should beat static"
        print("smoke OK: adaptive beats static on imbalance and time")


if __name__ == "__main__":
    main()
