"""Old vs fused second-order Newton path (the PR-3 tentpole claim).

Fits the same batch of sources with ``newton.fit_batch`` under two
objectives:

  * **old** — the ``jax`` backend, whose per-iteration evaluation is
    ``value_and_grad`` + ``vmap(jax.hessian)``: forward-over-reverse AD
    re-renders the whole patch pipeline ~27× per Newton iteration;
  * **fused** — a kernel backend (``pallas_interpret`` / ``ref`` on CPU,
    ``pallas`` on TPU), whose ``second_order`` renders the moments once,
    reads the per-pixel residuals + 2×2 curvature blocks from the fused
    ``poisson_elbo_hess`` kernel, and assembles the exact dense Hessian
    as MXU-batched contractions with one 6-direction density sweep.

``gtol=0`` pins both paths to exactly ``max_iters`` iterations so the
comparison is render-for-render.  Emits JSON with sources/sec,
iterations/sec and the derived renders-per-iteration (per-iteration wall
time over the measured cost of one batched moment render).

Run (either invocation works — ``benchmarks/common.py`` shims sys.path):

    python -m benchmarks.newton_fused --sources 256
    python benchmarks/newton_fused.py --smoke
"""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import batched_elbo, elbo, infer, newton, synthetic
from repro.core.priors import default_priors


def _problem(s: int, patch: int, seed: int = 0):
    priors = default_priors()
    sky = synthetic.sample_sky(jax.random.PRNGKey(seed), num_sources=s,
                               field=max(96, 4 * patch), priors=priors)
    x, corners = infer.extract_patches(sky.images, sky.metas,
                                       sky.truth.pos, patch)
    bg = jnp.broadcast_to(sky.metas.sky[None, :, None, None], x.shape)
    thetas = jax.vmap(lambda t: elbo.init_theta(t, priors))(sky.truth)
    return sky.metas, priors, thetas, x, bg, corners


def _time(fn, iters=1):
    out = jax.block_until_ready(fn())     # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters, out


def _render_time(backend, metas, thetas, corners, patch, iters=3):
    """Wall cost of ONE batched moment render — the unit for the
    renders-per-iteration metric."""
    if backend == "jax":
        fn = jax.jit(lambda th: batched_elbo._moments_jnp(
            th, corners, metas, patch)[0])
    else:
        fn = jax.jit(lambda th: batched_elbo._moments_kernel(
            th, corners, metas, patch, backend)[0])
    secs, _ = _time(lambda: fn(thetas), iters=iters)
    return secs


def run(backends_list, s, patch, max_iters, reps=1, seed=0):
    metas, priors, thetas, x, bg, corners = _problem(s, patch, seed)
    results = []
    for name in backends_list:
        obj = infer.make_objective(metas, priors, backend=name)
        # gtol=0: nothing converges, both paths execute exactly
        # max_iters iterations (+ the initial evaluation)
        fit = lambda: newton.fit_batch(obj, thetas, x, bg, corners,
                                       max_iters=max_iters, gtol=0.0)
        secs, res = _time(fit, iters=reps)
        t_render = _render_time(name, metas, thetas, corners, patch)
        per_iter = secs / (max_iters + 1)    # +1: initial evaluation
        results.append({
            "backend": name,
            "sources": s,
            "patch": patch,
            "n_img": int(x.shape[1]),
            "newton_iters": max_iters,
            "seconds_per_fit": secs,
            "sources_per_sec": s / secs,
            "iters_per_sec": s * max_iters / secs,
            "seconds_per_render": t_render,
            "renders_per_iteration": per_iter / t_render,
        })
    return results


def report(args):
    backends_list = [b.strip() for b in args.backends.split(",")]
    results = run(backends_list, args.sources, args.patch, args.max_iters,
                  reps=args.reps)
    by = {r["backend"]: r for r in results}
    old = by.get(args.baseline)
    speedups = {
        name: r["sources_per_sec"] / old["sources_per_sec"]
        for name, r in by.items() if old and name != args.baseline}
    return {
        "benchmark": "newton_fused",
        "metric": "sources/sec of the full trust-region Newton fit "
                  "(fixed iteration count, gtol=0)",
        "device": jax.devices()[0].platform,
        "baseline": args.baseline,
        "speedup_vs_baseline": speedups,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sources", type=int, default=256)
    ap.add_argument("--patch", type=int, default=16)
    ap.add_argument("--max-iters", type=int, default=4)
    ap.add_argument("--reps", type=int, default=1)
    ap.add_argument("--backends", default="jax,pallas_interpret")
    ap.add_argument("--baseline", default="jax")
    ap.add_argument("--smoke", action="store_true",
                    help="small problem; assert fused >= old sources/sec")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.sources, args.patch, args.max_iters = 32, 16, 2

    rep = report(args)
    text = json.dumps(rep, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.smoke:
        slow = [n for n, s in rep["speedup_vs_baseline"].items() if s < 1.0]
        assert not slow, (
            f"fused second-order path slower than {args.baseline}: "
            f"{rep['speedup_vs_baseline']}")
        print("SMOKE OK: fused >= baseline on sources/sec")
    return rep


def main_csv():
    """CSV rows for benchmarks/run.py (small configuration)."""
    rep = main(["--sources", "64", "--patch", "16", "--max-iters", "3"])
    for r in rep["results"]:
        common.emit(
            f"newton_fused.{r['backend']}", r["seconds_per_fit"] * 1e6,
            f"sources_per_sec={r['sources_per_sec']:.2f};"
            f"renders_per_iter={r['renders_per_iteration']:.1f}")


if __name__ == "__main__":
    main()
