"""Table I reproduction: Celeste vs the Photo-style heuristic on a
synthetic Stripe-82-like field (truth known by construction, standing in
for the paper's 30-exposure coadd ground truth).

Paper's claims to validate: Celeste better on position (~30%) and all
four colors (≥30%); heuristic may win brightness/scale.
"""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import jax
import jax.numpy as jnp

from benchmarks.common import emit, make_sky_and_catalog, timeit
from repro.core import heuristic, infer


def run(num_sources=16, field=160, seed=0):
    sky, est_h, priors = make_sky_and_catalog(seed, num_sources, field)
    err_h = heuristic.catalog_errors(est_h, sky.truth)

    def fit():
        thetas, stats = infer.run_inference(
            sky.images, sky.metas, est_h, priors, patch=24,
            batch=num_sources)
        return thetas, stats

    dt, (thetas, stats) = timeit(lambda: fit(), warmup=0, iters=1)
    cat = infer.infer_catalog(thetas)
    err_c = heuristic.catalog_errors(cat, sky.truth)

    rows = []
    for metric in ("position", "missed_gals", "missed_stars", "brightness",
                   "color_ug", "color_gr", "color_ri", "color_iz",
                   "profile", "eccentricity", "scale", "angle"):
        rows.append((metric, err_h[metric], err_c[metric]))
        emit(f"table1.{metric}", dt * 1e6 / num_sources,
             f"photo={err_h[metric]:.3f};celeste={err_c[metric]:.3f};"
             f"winner={'celeste' if err_c[metric] < err_h[metric] else 'photo'}")
    pos_gain = 1.0 - err_c["position"] / max(err_h["position"], 1e-9)
    emit("table1.position_improvement", dt * 1e6 / num_sources,
         f"celeste_vs_photo={pos_gain:.2%};paper_claim=~30%")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
