"""The utilization-gated speed ladder: default → autotuned → bf16.

Measures sources/sec of the full trust-region Newton fit (fixed
iteration count, ``gtol=0`` — render-for-render comparable) on one
kernel backend across three rungs:

  1. **baseline** — f32, the untuned kernel defaults (``BLOCK=32``
     sources per program, 128-lane minor-dim padding);
  2. **tuned**    — f32, block shapes from the ``kernels/tuning``
     autotuner sweep (cached on disk; re-swept here);
  3. **tuned+bf16** — tuned shapes plus the mixed-precision Hessian
     assembly (``precision="bf16"``).

``--smoke`` is the CI gate: a reduced 2-point sweep per knob, then
assert (a) the tuned rung is no slower than the BLOCK=32 default
(within ``--regression-threshold``), (b) the tuned+bf16 rung is
strictly faster than the baseline, and (c) the bf16 policy still
reproduces the golden-catalog fixture (its bf16 branch) at rtol 1e-4.
A regression in any of the three fails the build.

    python -m benchmarks.kernel_occupancy --sources 192
    python benchmarks/kernel_occupancy.py --smoke
"""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elbo, infer, newton, synthetic
from repro.core.priors import default_priors
from repro.kernels import tuning


def _problem(s: int, patch: int, seed: int = 0):
    priors = default_priors()
    sky = synthetic.sample_sky(jax.random.PRNGKey(seed), num_sources=s,
                               field=max(96, 4 * patch), priors=priors)
    x, corners = infer.extract_patches(sky.images, sky.metas,
                                       sky.truth.pos, patch)
    bg = jnp.broadcast_to(sky.metas.sky[None, :, None, None], x.shape)
    thetas = jax.vmap(lambda t: elbo.init_theta(t, priors))(sky.truth)
    return sky.metas, priors, thetas, x, bg, corners


def _time(fn, iters=1):
    out = jax.block_until_ready(fn())     # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters, out


def _rung(name, backend, metas, priors, thetas, x, bg, corners,
          max_iters, reps, precision=None, config=None):
    obj = infer.make_objective(metas, priors, backend=backend,
                               precision=precision, kernel_config=config)
    fit = lambda: newton.fit_batch(obj, thetas, x, bg, corners,
                                   max_iters=max_iters, gtol=0.0)
    secs, _ = _time(fit, iters=reps)
    s = int(thetas.shape[0])
    return {
        "rung": name,
        "backend": backend,
        "precision": precision or "f32",
        "config": dataclasses.asdict(config) if config else None,
        "sources": s,
        "patch": int(x.shape[-1]),
        "n_img": int(x.shape[1]),
        "newton_iters": max_iters,
        "seconds_per_fit": secs,
        "sources_per_sec": s / secs,
    }


def _golden_bf16_check(config: tuning.KernelConfig) -> dict:
    """Fit the golden problem under the bf16 policy (tuned shapes) and
    compare against the fixture's bf16 branch at rtol 1e-4."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    fixdir = os.path.join(root, "tests", "fixtures")
    if fixdir not in sys.path:
        sys.path.insert(0, fixdir)
    from gen_golden_catalog import fit_catalog

    cfg = dataclasses.replace(config, precision="bf16")
    _, cat = fit_catalog("pallas_interpret", kernel_config=cfg)
    golden = np.load(os.path.join(fixdir, "golden_catalog.npz"))
    checks = [
        ("pos", np.asarray(cat.pos), golden["bf16_pos"], 1e-3),
        ("ref_flux", np.asarray(cat.ref_flux), golden["bf16_ref_flux"], 0.0),
        ("colors", np.asarray(cat.colors), golden["bf16_colors"], 1e-4),
        ("is_gal", np.asarray(cat.is_gal), golden["bf16_is_gal"], 1e-3),
        ("gal_scale", np.asarray(cat.gal_scale), golden["bf16_gal_scale"],
         1e-4),
    ]
    out = {"rtol": 1e-4, "fields": {}, "ok": True}
    for name, got, want, atol in checks:
        err = float(np.max(np.abs(got - want)))
        ok = bool(np.allclose(got, want, rtol=1e-4, atol=atol))
        out["fields"][name] = {"max_abs_err": err, "atol": atol, "ok": ok}
        out["ok"] &= ok
    return out


def run(args) -> dict:
    backend = args.backend
    metas, priors, thetas, x, bg, corners = _problem(args.sources,
                                                     args.patch)
    n_img = int(x.shape[1])
    sweep_kw = {}
    if args.smoke:   # 2-point sweep per knob: default vs the CPU winner
        sweep_kw = dict(elbo_blocks=(32, 64), render_blocks=(1, 8))
    tuned_cfg, sweep = tuning.autotune(backend, args.sources, n_img,
                                       args.patch, **sweep_kw)

    common_args = (metas, priors, thetas, x, bg, corners,
                   args.max_iters, args.reps)
    ladder = [
        _rung("baseline_f32_block32", backend, *common_args,
              config=tuning.DEFAULT),
        _rung("tuned_f32", backend, *common_args, config=tuned_cfg),
        _rung("tuned_bf16", backend, *common_args, precision="bf16",
              config=tuned_cfg),
    ]
    base = ladder[0]["sources_per_sec"]
    rep = {
        "benchmark": "kernel_occupancy",
        "metric": "sources/sec of the fixed-iteration Newton fit",
        "device": jax.devices()[0].platform,
        "tuned_config": dataclasses.asdict(tuned_cfg),
        "sweep": {k: sweep[k] for k in ("elbo", "render", "winner")},
        "ladder": ladder,
        "speedup_vs_baseline": {
            r["rung"]: r["sources_per_sec"] / base for r in ladder},
    }
    if args.smoke or args.golden:
        rep["golden_bf16"] = _golden_bf16_check(tuned_cfg)
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sources", type=int, default=192)
    ap.add_argument("--patch", type=int, default=16)
    ap.add_argument("--max-iters", type=int, default=3)
    ap.add_argument("--reps", type=int, default=1)
    ap.add_argument("--backend", default=os.environ.get(
        "REPRO_ELBO_BACKEND") or "pallas_interpret")
    ap.add_argument("--golden", action="store_true",
                    help="also run the bf16 golden-catalog parity check")
    ap.add_argument("--regression-threshold", type=float, default=0.95,
                    help="tuned rung must reach this fraction of "
                         "baseline sources/sec")
    ap.add_argument("--smoke", action="store_true",
                    help="small problem + reduced sweep; assert the "
                         "ladder ordering and bf16 golden parity")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.sources, args.max_iters = 64, 2

    rep = run(args)
    print(json.dumps(rep, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            f.write(json.dumps(rep, indent=2) + "\n")
    if args.smoke:
        sp = rep["speedup_vs_baseline"]
        assert sp["tuned_f32"] >= args.regression_threshold, (
            f"tuned blocks slower than the BLOCK=32 default: {sp}")
        assert sp["tuned_bf16"] > 1.0, (
            f"tuned+bf16 rung not faster than the f32 baseline: {sp}")
        assert rep["golden_bf16"]["ok"], (
            f"bf16 golden-catalog parity failed: {rep['golden_bf16']}")
        print("SMOKE OK: ladder ordering + bf16 golden parity hold")
    return rep


def main_csv():
    """CSV rows for benchmarks/run.py (small configuration)."""
    rep = main(["--sources", "64", "--max-iters", "2"])
    for r in rep["ladder"]:
        common.emit(
            f"kernel_occupancy.{r['rung']}", r["seconds_per_fit"] * 1e6,
            f"sources_per_sec={r['sources_per_sec']:.2f};"
            f"speedup={rep['speedup_vs_baseline'][r['rung']]:.2f}")


if __name__ == "__main__":
    main()
