"""Figure 5 reproduction: strong scaling — the paper's 332,631-source
region over 16→256 nodes, runtime component breakdown."""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import numpy as np

from benchmarks.common import emit
from benchmarks.scaling_sim import (clustered_positions, simulate,
                                    simulate_adaptive, synth_sky_costs,
                                    synth_sky_workload)
from repro.core.decompose import CostModel

TOTAL_SOURCES = 332_631     # paper §VI-C


def main():
    rng = np.random.default_rng(1)
    pos = clustered_positions(rng, TOTAL_SOURCES, extent=65536.0)
    costs = synth_sky_costs(rng, TOTAL_SOURCES)
    feats, lcosts = synth_sky_workload(rng, TOTAL_SOURCES, positions=pos,
                                       extent=65536.0)
    base = None
    for nodes in (16, 32, 64, 128, 256):
        r = simulate(pos, costs, nodes)
        if base is None:
            base = r.total_time * nodes
        eff = base / (r.total_time * nodes)
        emit(f"fig5.nodes{nodes}", r.total_time * 1e6,
             f"opt={r.optimize_time:.1f}s;imb={r.imbalance_time:.1f}s;"
             f"fetch={r.fetch_time:.1f}s;sched={r.sched_time:.2f}s;"
             f"parallel_eff={eff:.2%};sps={r.sources_per_sec:.1f}")
        st = simulate(pos, lcosts, nodes,
                      plan_costs=CostModel().predict(feats))
        ad = simulate_adaptive(pos, feats, lcosts, nodes)
        emit(f"fig5.nodes{nodes}.adaptive", ad.total_time * 1e6,
             f"static_imb={st.imbalance_time / st.total_time:.2%};"
             f"adaptive_imb={ad.imbalance_time / ad.total_time:.2%};"
             f"static_sps={st.sources_per_sec:.1f};"
             f"adaptive_sps={ad.sources_per_sec:.1f}")


if __name__ == "__main__":
    main()
