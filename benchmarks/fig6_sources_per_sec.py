"""Figure 6 reproduction: sources/second vs node count, and the §III-C
decomposition comparison — source-level batches (chosen strategy) vs
equal-area sky regions (rejected strategy), on a clustered sky."""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import numpy as np

from benchmarks.common import emit
from benchmarks.scaling_sim import (clustered_positions, simulate,
                                    synth_sky_costs)


def main():
    rng = np.random.default_rng(2)
    n = 65_536
    pos = clustered_positions(rng, n, extent=32768.0)
    costs = synth_sky_costs(rng, n)
    for nodes in (16, 64, 256):
        src = simulate(pos, costs, nodes, strategy="source")
        reg = simulate(pos, costs, nodes, strategy="region")
        emit(f"fig6.nodes{nodes}", src.total_time * 1e6,
             f"sps_source={src.sources_per_sec:.1f};"
             f"sps_region={reg.sources_per_sec:.1f};"
             f"speedup={src.sources_per_sec / reg.sources_per_sec:.2f}x;"
             f"imb_source={src.imbalance_time / src.total_time:.2%};"
             f"imb_region={reg.imbalance_time / reg.total_time:.2%}")


if __name__ == "__main__":
    main()
