"""End-to-end survey pipeline benchmark: fields/sec + detection quality.

Runs the full detection → seeding → inference → stitching pipeline
(``core/pipeline.run_pipeline``) over a synthetic multi-field survey with
NO oracle positions, and reports throughput plus the catalog-quality
gates: detection/stitched completeness and purity vs the synthetic truth,
duplicate fits in overlap regions, and the retrieval-component split
(total vs consumer-blocking fetch seconds — prefetch should hide nearly
all of it).

``--smoke`` is the CI acceptance assertion: completeness ≥ 90 %, purity
≥ 90 %, ZERO duplicate fits, every field processed.  JSON lands in
``--out``; ``main_csv`` emits the runner's CSV rows.
"""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import pipeline, synthetic
from repro.core.priors import default_priors


def run(grid=(2, 2), field=96, overlap=32, sources_per_field=6,
        patch=24, batch=8, seed=0, bright=True) -> dict:
    priors = synthetic.bright_priors() if bright else default_priors()
    survey = synthetic.sample_survey(
        jax.random.PRNGKey(seed), grid=grid, field=field, overlap=overlap,
        sources_per_field=sources_per_field, priors=priors)
    t0 = time.perf_counter()
    res = pipeline.run_pipeline(survey, priors, patch=patch, batch=batch)
    wall = time.perf_counter() - t0
    st = res.stats
    fetch = st.fetch
    return {
        "grid": list(grid), "field": field, "overlap": overlap,
        "n_truth": int(np.asarray(survey.truth.pos).shape[0]),
        "n_catalog": int(np.asarray(res.catalog.pos).shape[0]),
        "fields_run": st.fields_run,
        "wall_seconds": wall,
        "fields_per_sec": st.fields_run / wall,
        "detect_seconds": sum(r.detect_seconds for r in st.fields),
        "fit_seconds": sum(r.fit_seconds for r in st.fields),
        "fetch_seconds": fetch.fetch_seconds,
        "fetch_blocked_seconds": fetch.blocked_seconds,
        "prefetch_hits": fetch.prefetch_hits,
        "duplicates_removed": st.duplicates_removed,
        "completeness": st.metrics["completeness"],
        "purity": st.metrics["purity"],
        "duplicates": st.metrics["duplicates"],
        "converged": sum(r.n_converged for r in st.fields),
        "fit": sum(r.n_owned for r in st.fields),
        # REPRO_CHECKIFY=1 harvest (empty when the mode is off)
        "checkify_errors": list(st.checkify_errors),
    }


def main_csv():
    r = run()
    emit("pipeline_e2e.2x2", r["wall_seconds"] * 1e6,
         f"fields={r['fields_run']};fps={r['fields_per_sec']:.3f};"
         f"completeness={r['completeness']:.2f};purity={r['purity']:.2f};"
         f"dups={r['duplicates']};"
         f"fetch_blocked={r['fetch_blocked_seconds']:.3f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", default="2x2")
    ap.add_argument("--field", type=int, default=96)
    ap.add_argument("--overlap", type=int, default=32)
    ap.add_argument("--sources-per-field", type=int, default=6)
    ap.add_argument("--patch", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default="/tmp/pipeline_e2e.json")
    ap.add_argument("--smoke", action="store_true",
                    help="assert the CI acceptance gate: completeness and "
                         "purity ≥ 0.9, zero duplicate fits, all fields "
                         "processed")
    args = ap.parse_args()
    grid = tuple(int(g) for g in args.grid.split("x"))
    r = run(grid=grid, field=args.field, overlap=args.overlap,
            sources_per_field=args.sources_per_field, patch=args.patch,
            batch=args.batch)
    print(json.dumps(r, indent=1))
    with open(args.out, "w") as f:
        json.dump(r, f, indent=1)
    if args.smoke:
        assert r["fields_run"] == grid[0] * grid[1], r
        assert r["completeness"] >= 0.9, r
        assert r["purity"] >= 0.9, r
        assert r["duplicates"] == 0, r
        # under REPRO_CHECKIFY=1 the sanitizer must come back clean
        assert r["checkify_errors"] == [], r["checkify_errors"]
        print("SMOKE OK: completeness "
              f"{r['completeness']:.2f}, purity {r['purity']:.2f}, "
              f"0 duplicates over {r['fields_run']} fields")


if __name__ == "__main__":
    main()
