"""Backend comparison for the batched ELBO hot path.

Measures sources/sec of ``BatchedObjective.value_and_grad`` — the
per-iteration evaluation the trust-region Newton loop pays — for each
ELBO backend (``core/backends.py``) across patch sizes and batch sizes,
and emits a JSON comparison.

CPU note: ``pallas_interpret`` runs the kernels in the Pallas interpreter
and is orders of magnitude slower than compiled code — on CPU it
validates the pipeline, it does not represent TPU performance.  On a TPU
host add ``--backends jax,pallas`` for the real comparison.

Run:
    PYTHONPATH=src python benchmarks/elbo_backends.py \
        --backends jax,pallas_interpret --patches 16,24 --batches 4,8
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

try:
    from benchmarks.common import timeit
except ImportError:        # invoked as `python benchmarks/elbo_backends.py`
    from common import timeit  # (also shims repo root + src onto sys.path)
from repro.core import elbo, infer, synthetic
from repro.core.priors import default_priors


def _problem(patch: int, batch: int, seed: int = 0):
    priors = default_priors()
    sky = synthetic.sample_sky(jax.random.PRNGKey(seed), num_sources=batch,
                               field=max(96, 4 * patch), priors=priors)
    x, corners = infer.extract_patches(sky.images, sky.metas,
                                       sky.truth.pos, patch)
    bg = jnp.broadcast_to(sky.metas.sky[None, :, None, None], x.shape)
    thetas = jax.vmap(lambda s: elbo.init_theta(s, priors))(sky.truth)
    return sky.metas, priors, thetas, x, bg, corners


def run(backends_list, patches, batches, iters=3):
    results = []
    for patch in patches:
        for batch in batches:
            metas, priors, thetas, x, bg, corners = _problem(patch, batch)
            for name in backends_list:
                obj = infer.make_objective(metas, priors, backend=name)
                fn = jax.jit(obj.value_and_grad)
                secs, _ = timeit(fn, thetas, x, bg, corners, warmup=1,
                                 iters=iters)
                results.append({
                    "backend": name,
                    "patch": patch,
                    "batch": batch,
                    "n_img": int(x.shape[1]),
                    "seconds_per_call": secs,
                    "sources_per_sec": batch / secs,
                })
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backends", default="jax,pallas_interpret")
    ap.add_argument("--patches", default="16,24")
    ap.add_argument("--batches", default="4,8")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args()

    results = run([b.strip() for b in args.backends.split(",")],
                  [int(p) for p in args.patches.split(",")],
                  [int(b) for b in args.batches.split(",")],
                  iters=args.iters)
    report = {
        "benchmark": "elbo_backends",
        "metric": "sources_per_sec of value_and_grad (Newton hot path)",
        "device": jax.devices()[0].platform,
        "results": results,
    }
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
