"""Figure 3 reproduction: intra-node scaling of sources/second.

The paper strong-scales 154 sources over 1–16 Julia threads and hits a
serial-GC wall beyond 4 threads.  The TPU adaptation batches sources with
``vmap`` — this benchmark sweeps the batch width and reports sources/sec.
There is no GC term under jit (DESIGN.md §2.4); the analogous ceiling is
the masked ``while_loop`` running until the *slowest* source in the batch
converges, so sources/sec saturates (rather than degrades) once batches
mix hard and easy sources.
"""
from __future__ import annotations

try:
    from benchmarks import common  # noqa: F401  (repo-root/src sys.path shim)
except ImportError:                # script-path invocation
    import common                  # noqa: F401

import time

import jax

from benchmarks.common import emit, make_sky_and_catalog
from repro.core import elbo, infer, newton


def main():
    num = 32
    sky, est_h, priors = make_sky_and_catalog(1, num_sources=num,
                                              field=224)
    x, corners = infer.extract_patches(sky.images, sky.metas, est_h.pos,
                                       24)
    from repro.core.synthetic import render_total
    total = render_total(est_h, sky.metas, 224)
    expd, _ = infer.extract_patches(total, sky.metas, est_h.pos, 24)
    import jax.numpy as jnp
    from repro.core.model import render_source_patch
    own = jax.jit(jax.vmap(lambda s, cs: jax.vmap(
        lambda m, c: render_source_patch(s, m, c, 24))(sky.metas, cs)))(
            est_h, corners)
    bg = jnp.maximum(expd - own, 1e-3)
    thetas = jax.jit(jax.vmap(lambda s: elbo.init_theta(s, priors)))(est_h)
    objective = infer.make_objective(sky.metas, priors)

    for width in (1, 2, 4, 8, 16, 32):
        idx = jnp.arange(width) % num
        args = (thetas[idx], x[idx], bg[idx], corners[idx])
        fit = lambda: newton.fit_batch(objective, *args, max_iters=50)
        jax.block_until_ready(fit().theta)      # compile
        t0 = time.perf_counter()
        res = fit()
        jax.block_until_ready(res.theta)
        dt = time.perf_counter() - t0
        sps = width / dt
        emit(f"fig3.batch{width}", dt * 1e6,
             f"sources_per_sec={sps:.2f};max_iters={int(res.iters.max())}")


if __name__ == "__main__":
    main()
